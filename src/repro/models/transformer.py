"""Decoder-only transformer LM (dense + VLM variants), scanned layers.

Covers minitron-8b, gemma2-9b (local/global alternation + softcaps),
glm4-9b, granite-34b (MQA), qwen2-vl-7b (M-RoPE + patch-embed frontend
stub). MoE archs reuse this skeleton with the MLP swapped
(:mod:`repro.models.moe`).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ArchConfig

Params = dict[str, Any]


def init_layer(rng, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(rng)
    return {
        "attn_norm": L.init_norm(cfg),
        "attn": L.init_attention(k1, cfg),
        "mlp_norm": L.init_norm(cfg),
        "mlp": L.init_mlp(k2, cfg),
    }


def init_params(rng, cfg: ArchConfig) -> Params:
    ke, kl = jax.random.split(rng)
    layer_rngs = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda r: init_layer(r, cfg))(layer_rngs)
    return {
        "embed": L.init_embedding(ke, cfg),
        "layers": stacked,
        "final_norm": L.init_norm(cfg),
    }


def _layer_window(cfg: ArchConfig, layer_idx, seq_len: int):
    """Sliding window size per layer as a traced value.

    gemma2 alternates local (even) / global (odd) layers; global layers get
    an "infinite" window (> seq_len) so the same flash kernel serves both.
    """
    if not cfg.local_global:
        return jnp.int32(seq_len + 1)  # full attention on every layer
    local = jnp.int32(cfg.sliding_window)
    glob = jnp.int32(seq_len + 1)
    return jnp.where(layer_idx % 2 == 0, local, glob)


def apply_layer(lp: Params, x, cfg: ArchConfig, layer_idx, *, positions3=None):
    from repro.dist.sharding import constrain

    s = x.shape[1]
    window = _layer_window(cfg, layer_idx, s)
    h = L.rms_norm(x, lp["attn_norm"]["scale"], cfg.norm_eps)
    h = L.attention_block(lp["attn"], h, cfg, layer_window=window,
                          positions3=positions3)
    x = constrain(x + h, "batch", None, None)
    h = L.rms_norm(x, lp["mlp_norm"]["scale"], cfg.norm_eps)
    h = L.mlp_block(lp["mlp"], h, cfg)
    return constrain(x + h, "batch", None, None)


def forward(params: Params, tokens, cfg: ArchConfig, *, patch_embeds=None,
            positions3=None):
    """Train/prefill forward: logits (B, S, vocab)."""
    x = L.embed(params["embed"], tokens, cfg)
    if cfg.family == "vlm" and patch_embeds is not None:
        # Stubbed modality frontend: precomputed patch embeddings replace
        # the first n_patches token slots (dynamic-resolution pipeline
        # would provide these; backbone cost is identical).
        n_p = patch_embeds.shape[1]
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x[:, n_p:]], axis=1)
    if cfg.mrope and positions3 is None:
        pos = jnp.arange(x.shape[1])[None, :]
        positions3 = jnp.stack([pos, pos, pos])  # text-only stream: t=h=w

    layer_fn = functools.partial(apply_layer, cfg=cfg, positions3=positions3)
    if cfg.remat:
        layer_fn = jax.checkpoint(
            layer_fn, policy=jax.checkpoint_policies.nothing_saveable
        )

    def scan_body(carry, inp):
        lp, idx = inp
        return layer_fn(lp, carry, layer_idx=idx), None

    x, _ = jax.lax.scan(
        scan_body, x, (params["layers"], jnp.arange(cfg.n_layers))
    )
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return L.unembed(params["embed"], x, cfg)


# ------------------------------------------------------------- decoding ---
def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    kv, hd = cfg.n_kv, cfg.head_dim
    if cfg.local_global and cfg.local_global_split_cache \
            and cfg.n_layers % 2 == 0:
        # Split cache (§Perf Cell 2): local (even) layers keep only a
        # sliding-window ring buffer — for gemma2 decode_32k that is
        # 21×4096 instead of 21×32768 slots (cache bytes ×0.56, and the
        # local layers' per-token read drops 8×).
        half = cfg.n_layers // 2
        wlen = min(cfg.sliding_window, max_len)
        return {
            "k_local": jnp.zeros((half, batch, wlen, kv, hd), dtype),
            "v_local": jnp.zeros((half, batch, wlen, kv, hd), dtype),
            "k": jnp.zeros((half, batch, max_len, kv, hd), dtype),
            "v": jnp.zeros((half, batch, max_len, kv, hd), dtype),
        }
    shape = (cfg.n_layers, batch, max_len, kv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_step(params: Params, cache, token, cache_len, cfg: ArchConfig):
    """One-token decode. token: (B, 1) int32; cache_len: filled length
    *including* the new token's slot. Returns (logits, new_cache).

    Caches ride the layer scan as xs/ys (scan-stacked): under SPMD each
    layer updates its 33 MB slice locally. (Carry-threading the whole
    cache with a traced layer index was tried and REFUTED — GSPMD turns
    the dynamic update on a sharded carry into full-cache selects, 19×
    worse; see EXPERIMENTS.md §Perf Cell 2.)
    """
    if cfg.local_global and "k_local" in cache:
        return _decode_step_local_global(params, cache, token, cache_len,
                                         cfg)
    x = L.embed(params["embed"], token, cfg)
    pos = (cache_len - 1) * jnp.ones((x.shape[0], 1), jnp.int32)

    def body(carry, inp):
        x = carry
        lp, kc, vc, idx = inp
        h = L.rms_norm(x, lp["attn_norm"]["scale"], cfg.norm_eps)
        q, k, v = L.qkv_project(lp["attn"], h, cfg)
        if cfg.mrope:
            p3 = jnp.stack([pos, pos, pos])
            q = L.apply_mrope(q, p3, cfg.rope_theta, cfg.mrope_sections)
            k = L.apply_mrope(k, p3, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = L.apply_rope(q, pos, cfg.rope_theta)
            k = L.apply_rope(k, pos, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(
            kc, k.astype(kc.dtype), (0, cache_len - 1, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            vc, v.astype(vc.dtype), (0, cache_len - 1, 0, 0))
        window = _layer_window(cfg, idx, kc.shape[1])
        o = L.decode_attention(q, kc, vc, cache_len, window=window,
                               softcap_val=cfg.attn_softcap)
        cd = L.dtype_of(cfg, "compute_dtype")
        x = x + (o.reshape(o.shape[0], 1, -1) @ lp["attn"]["wo"].astype(cd))
        h = L.rms_norm(x, lp["mlp_norm"]["scale"], cfg.norm_eps)
        x = x + L.mlp_block(lp["mlp"], h, cfg)
        return x, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x,
        (params["layers"], cache["k"], cache["v"], jnp.arange(cfg.n_layers)),
    )
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg)
    return logits, {"k": k_new, "v": v_new}


def _decode_step_local_global(params, cache, token, cache_len,
                              cfg: ArchConfig):
    """Split-cache decode for alternating local/global archs (gemma2):
    even layers attend through a sliding-window ring buffer, odd layers
    through the full cache. Layers are scanned in (local, global) pairs."""
    x = L.embed(params["embed"], token, cfg)
    pos = (cache_len - 1) * jnp.ones((x.shape[0], 1), jnp.int32)
    cd = L.dtype_of(cfg, "compute_dtype")
    wlen = cache["k_local"].shape[2]
    slot = (cache_len - 1) % wlen
    filled = jnp.minimum(cache_len, wlen)
    pairs = jax.tree.map(
        lambda a: a.reshape(cfg.n_layers // 2, 2, *a.shape[1:]),
        params["layers"])

    def attn_sub(lp, x, kc, vc, *, write_at, read_len, window):
        h = L.rms_norm(x, lp["attn_norm"]["scale"], cfg.norm_eps)
        q, k, v = L.qkv_project(lp["attn"], h, cfg)
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k = L.apply_rope(k, pos, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                          (0, write_at, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                          (0, write_at, 0, 0))
        o = L.decode_attention(q, kc, vc, read_len, window=window,
                               softcap_val=cfg.attn_softcap)
        x = x + (o.reshape(o.shape[0], 1, -1) @ lp["attn"]["wo"].astype(cd))
        h = L.rms_norm(x, lp["mlp_norm"]["scale"], cfg.norm_eps)
        return x + L.mlp_block(lp["mlp"], h, cfg), kc, vc

    def body(carry, inp):
        x = carry
        lpair, kl, vl, kg, vg = inp
        lp_local = jax.tree.map(lambda a: a[0], lpair)
        lp_global = jax.tree.map(lambda a: a[1], lpair)
        # Ring slots hold exactly the last `wlen` tokens ⇒ no extra mask.
        x, kl, vl = attn_sub(lp_local, x, kl, vl, write_at=slot,
                             read_len=filled, window=None)
        x, kg, vg = attn_sub(lp_global, x, kg, vg, write_at=cache_len - 1,
                             read_len=cache_len, window=None)
        return x, (kl, vl, kg, vg)

    x, (kl, vl, kg, vg) = jax.lax.scan(
        body, x, (pairs, cache["k_local"], cache["v_local"],
                  cache["k"], cache["v"]))
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg)
    return logits, {"k_local": kl, "v_local": vl, "k": kg, "v": vg}
