"""Family-dispatching model API: init / forward / loss / cache / decode.

Every architecture family exposes the same four entry points so the
launcher, dry-run, and trainer are arch-agnostic.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import hybrid, mamba2, moe, transformer, whisper
from repro.models.config import ArchConfig, InputShape

Params = Any


def init_params(rng, cfg: ArchConfig) -> Params:
    if cfg.family == "moe":
        return moe.init_params(rng, cfg)
    if cfg.family == "ssm":
        return mamba2.init_params(rng, cfg)
    if cfg.family == "hybrid":
        return hybrid.init_params(rng, cfg)
    if cfg.family == "audio":
        return whisper.init_params(rng, cfg)
    return transformer.init_params(rng, cfg)  # dense + vlm


def forward_logits(params, batch: dict, cfg: ArchConfig):
    """Returns (logits, aux_loss)."""
    tokens = batch["tokens"]
    if cfg.family == "moe":
        return moe.forward(params, tokens, cfg)
    if cfg.family == "ssm":
        return mamba2.forward(params, tokens, cfg), 0.0
    if cfg.family == "hybrid":
        return hybrid.forward(params, tokens, cfg), 0.0
    if cfg.family == "audio":
        return whisper.forward(params, tokens, cfg,
                               frame_embeds=batch["frame_embeds"]), 0.0
    if cfg.family == "vlm":
        return transformer.forward(params, tokens, cfg,
                                   patch_embeds=batch.get("patch_embeds")), 0.0
    return transformer.forward(params, tokens, cfg), 0.0


def loss_fn(params, batch: dict, cfg: ArchConfig):
    """Next-token cross-entropy (+ MoE aux)."""
    logits, aux = forward_logits(params, batch, cfg)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + cfg.router_aux_coef * aux


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    if cfg.family == "moe":
        return moe.init_cache(cfg, batch, max_len, dtype)
    if cfg.family == "ssm":
        return mamba2.init_cache(cfg, batch, max_len)
    if cfg.family == "hybrid":
        return hybrid.init_cache(cfg, batch, max_len, dtype)
    if cfg.family == "audio":
        return whisper.init_cache(cfg, batch, max_len, dtype)
    return transformer.init_cache(cfg, batch, max_len, dtype)


def decode_step(params, cache, token, cache_len, cfg: ArchConfig):
    if cfg.family == "moe":
        return moe.decode_step(params, cache, token, cache_len, cfg)
    if cfg.family == "ssm":
        return mamba2.decode_step(params, cache, token, cache_len, cfg)
    if cfg.family == "hybrid":
        return hybrid.decode_step(params, cache, token, cache_len, cfg)
    if cfg.family == "audio":
        return whisper.decode_step(params, cache, token, cache_len, cfg)
    return transformer.decode_step(params, cache, token, cache_len, cfg)


# ------------------------------------------------------------ input specs --
def train_input_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for one global train/prefill batch."""
    b, s = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.family == "audio":
        specs["frame_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_audio_ctx, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_patches or 256, cfg.d_model), jnp.float32)
    return specs


def decode_input_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    """Stand-ins for one decode step with a cache of seq_len history."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        lambda: init_cache(cfg, b, s, dtype=jnp.bfloat16))
    return {
        "token": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "cache_len": jax.ShapeDtypeStruct((), jnp.int32),
        "cache": cache,
    }
