"""Quickstart: hybrid SpMM/SDDMM on one matrix in four lines each.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import LibraSDDMM, LibraSpMM, nnz1_fraction
from repro.kernels import ref
from repro.sparse.generate import mixed_csr


def main() -> None:
    rng = np.random.default_rng(0)
    a = mixed_csr(256, 256, seed=1)  # hybrid-regime matrix (paper Fig. 1)
    print(f"matrix: {a.shape}, nnz={a.nnz}, "
          f"NNZ-1 fraction={nnz1_fraction(a):.2f}")

    # --- SpMM: C = A @ B ------------------------------------------------
    b = jnp.asarray(rng.standard_normal((a.k, 128)).astype(np.float32))
    spmm = LibraSpMM(a)                       # preprocess + autotune once
    cfg = spmm.tune_config                    # the model-tuned plan choice
    print(f"tuned: threshold={cfg.threshold} kt={cfg.kt} nt={cfg.nt} "
          f"grid_order={cfg.grid_order} (source={cfg.source})")
    c = spmm(b)                               # fast XLA path
    c_pallas = spmm(b, backend="pallas")      # Pallas TPU kernels (interpret)
    oracle = ref.spmm_dense_oracle(a.to_dense(), np.asarray(b))
    print(f"SpMM: tc_ratio={spmm.tc_ratio:.2f} "
          f"max_err_xla={np.abs(np.asarray(c) - oracle).max():.2e} "
          f"max_err_pallas={np.abs(np.asarray(c_pallas) - oracle).max():.2e}")

    # --- SDDMM: vals = sample(X @ Yᵀ, A) --------------------------------
    x = jnp.asarray(rng.standard_normal((a.m, 64)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((a.k, 64)).astype(np.float32))
    sddmm = LibraSDDMM(a)
    vals = sddmm(x, y)
    so = ref.sddmm_dense_oracle(a.to_dense(), np.asarray(x), np.asarray(y))
    print(f"SDDMM: tc_ratio={sddmm.tc_ratio:.2f} "
          f"max_err={np.abs(np.asarray(vals) - so).max():.2e}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
