"""End-to-end LM training driver: trains a ~100M-param dense transformer
for a few hundred steps through the full production stack (sharded
train_step, AdamW, checkpointing + resume, deterministic data pipeline).

    PYTHONPATH=src python examples/lm_pretrain.py --steps 300
    (defaults are sized for CPU; drop --steps for a quick pass)
"""
import argparse

from repro.launch.train import train_loop
from repro.models.config import ArchConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    # ~100M params: 12L × d768 (GPT-2-small-ish), GQA 12h/4kv.
    cfg = ArchConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv=4, d_ff=3072, vocab=32768, attn_chunk=256,
        remat=False,
    )
    _, losses = train_loop(cfg, args.steps, args.batch, args.seq,
                           ckpt_dir=args.ckpt_dir, resume=True,
                           log_every=20, save_every=100)
    print(f"[lm_pretrain] loss {losses[0]:.3f} → {losses[-1]:.3f} "
          f"over {len(losses)} steps")
    assert losses[-1] < losses[0]
    print("lm_pretrain OK")


if __name__ == "__main__":
    main()
