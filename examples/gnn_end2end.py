"""End-to-end GNN training driver (the paper's §5.5 case study):
trains GCN and AGNN on a synthetic power-law graph, every sparse matmul
running through Libra hybrid operators (forward SpMM/SDDMM, backward via
the transpose-plan SpMM + SDDMM duality).

    PYTHONPATH=src python examples/gnn_end2end.py --steps 60
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import gnn
from repro.sparse import power_law_csr


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=1024)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--feat", type=int, default=64)
    ap.add_argument("--classes", type=int, default=8)
    args = ap.parse_args()

    a = power_law_csr(args.nodes, args.nodes, 10.0, seed=1)
    rng = np.random.default_rng(0)
    feats = jnp.asarray(rng.standard_normal((a.m, args.feat)).astype(np.float32))
    # planted community labels → learnable signal
    labels = jnp.asarray(rng.integers(0, args.classes, a.m))

    t0 = time.perf_counter()
    gops = gnn.GraphOps(a)
    print(f"preprocessed graph: nnz={a.nnz} "
          f"spmm_tc_ratio={gops.arrs['tc_vals'].shape[0]} blocks "
          f"({time.perf_counter() - t0:.3f}s, reused every step)")

    norm = jnp.asarray(gnn.gcn_norm_edges(a))

    def ce(logits):
        lp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(lp, labels[:, None], 1).mean()

    for model_name, init, fwd, steps in (
        ("GCN", gnn.init_gcn, lambda p: gnn.gcn_forward(p, gops, feats, norm),
         args.steps),
        ("AGNN", gnn.init_agnn, lambda p: gnn.agnn_forward(p, gops, feats),
         max(args.steps // 3, 5)),
    ):
        params = init(jax.random.PRNGKey(0), [args.feat, 64, args.classes])
        vg = jax.jit(jax.value_and_grad(lambda p: ce(fwd(p))))
        t0 = time.perf_counter()
        first = last = None
        for s in range(steps):
            loss, g = vg(params)
            params = jax.tree.map(lambda p, gg: p - 0.3 * gg, params, g)
            first = first if first is not None else float(loss)
            last = float(loss)
        jax.block_until_ready(params)
        dt = time.perf_counter() - t0
        acc = float((jnp.argmax(fwd(params), -1) == labels).mean())
        print(f"{model_name}: {steps} steps in {dt:.2f}s "
              f"loss {first:.3f}→{last:.3f} train_acc={acc:.2f}")
        assert last < first, "training must reduce the loss"
    print("gnn_end2end OK")


if __name__ == "__main__":
    main()
