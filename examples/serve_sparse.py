"""Sparse-operator serving demo: two graphs, many tenants, one engine.

Registers two graphs (a GNN-style power-law graph and a FEM-style mixed
matrix) in a :class:`~repro.serve.registry.GraphRegistry`, warms the
AOT executables, then drives a mixed burst of SpMM/SDDMM requests from
three "tenants" through the panel-bucketed
:class:`~repro.serve.engine.SparseEngine` — plus a trained-GCN
node-scoring round through :class:`~repro.serve.gnn_service.GNNService`
— and prints the serving stats (throughput, padding waste, bucket
occupancy, cache hits).

A fourth "chaotic" tenant then demonstrates the resilience layer: its
requests carry deadlines, and an injected
:class:`~repro.serve.faults.FaultPlan` crashes the fast packed apply —
the engine degrades down the bit-equivalent ladder (packed → singles →
unsegmented → xla), the expired request is dropped with a typed
``DeadlineExceeded`` result, and ``engine.health()`` shows the
breaker/degradation accounting.

The whole demo runs under a :class:`~repro.obs.trace.Tracer`: at the
end it prints the engine's Prometheus exposition and dumps the full
request lifecycle (``serve.admit`` → ``serve.flush`` → ``bucket`` →
``execute`` → ``apply`` → ``serve.complete``, linked per request by
Perfetto flow events) as a Chrome-trace JSON you can open in Perfetto
— then scrapes the same metrics back over HTTP from the engine's
zero-dependency observability endpoint
(:meth:`~repro.serve.engine.SparseEngine.serve_http`: ``/metrics``,
``/health``, ``/explain/<graph>``).

    PYTHONPATH=src python examples/serve_sparse.py
"""
import json
import os
import tempfile
import urllib.request

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import gnn as mgnn
from repro.obs import Tracer
from repro.serve import (
    FaultPlan,
    FaultRule,
    GNNService,
    GraphRegistry,
    ServeError,
    SparseEngine,
)
from repro.sparse.generate import mixed_csr, power_law_csr


def main() -> None:
    rng = np.random.default_rng(0)
    graph = power_law_csr(1024, 1024, 12.0, seed=1)   # social-graph regime
    fem = mixed_csr(768, 640, seed=2)                 # FEM/hybrid regime

    registry = GraphRegistry(max_graphs=8, width_buckets=(32, 64, 128),
                             panel_buckets=(1, 2, 4, 8))
    registry.register(graph, name="tenantA/social", warm_widths=(64,))
    registry.register(fem, name="tenantB/fem")
    registry.register(graph, name="tenantC/social-alias")  # shared plan

    # trace the whole serving session: every request's lifecycle shows
    # up as serve.admit/flush/bucket/execute/apply spans + a
    # serve.complete marker per answered rid
    tracer = Tracer()
    engine = SparseEngine(registry, tracer=tracer)

    # --- a mixed burst: three tenants, ragged widths, both operators
    rids = {}
    for i in range(6):
        b = jnp.asarray(rng.standard_normal(
            (graph.k, (48, 64, 57)[i % 3])).astype(np.float32))
        who = ("tenantA/social", "tenantC/social-alias")[i % 2]
        rids[engine.submit(who, "spmm", b=b)] = who
    for i in range(3):
        b = jnp.asarray(rng.standard_normal(
            (fem.k, 96)).astype(np.float32))
        rids[engine.submit("tenantB/fem", "spmm", b=b)] = "tenantB/fem"
    x = jnp.asarray(rng.standard_normal((fem.m, 32)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((fem.k, 32)).astype(np.float32))
    rids[engine.submit("tenantB/fem", "sddmm", x=x, y=y)] = "tenantB/fem"

    results = engine.flush()
    assert sorted(results) == sorted(rids)
    print(f"served {len(results)} requests "
          f"({sum(v.size for v in results.values())} output elements)")

    # --- trained-GCN scoring through the same engine
    service = GNNService(engine)
    params = mgnn.init_gcn(jax.random.PRNGKey(0), [64, 64, 16])
    service.register_gcn("tenantA/gcn", graph, params)
    feats = jnp.asarray(rng.standard_normal(
        (graph.m, 64)).astype(np.float32))
    s1 = service.submit("tenantA/gcn", feats, node_ids=np.arange(10))
    s2 = service.submit("tenantA/gcn", feats * 0.5, node_ids=np.arange(10))
    scores = service.flush()
    print(f"gcn scores for 10 nodes, 2 concurrent requests: "
          f"{np.asarray(scores[s1])[0, :4].round(3).tolist()} ...")
    assert scores[s2].shape == (10, 16)

    # --- tenant D: deadlines + an injected fast-path fault. The engine
    #     is resilient by default; the fault plan makes the packed apply
    #     crash once, so the bucket degrades to per-request singles
    #     (bit-identical results), while a request admitted with an
    #     already-hopeless deadline is dropped with a typed result.
    plan = FaultPlan([FaultRule(kth=1, graph="tenantB/fem", op="spmm",
                                strategy="fast")])
    engine.faults = plan
    good = [engine.submit("tenantB/fem", "spmm",
                          b=jnp.asarray(rng.standard_normal(
                              (fem.k, 64)).astype(np.float32)),
                          deadline_ms=10_000.0) for _ in range(3)]
    import time as _time

    doomed = engine.submit("tenantB/fem", "spmm",
                           b=jnp.asarray(rng.standard_normal(
                               (fem.k, 64)).astype(np.float32)),
                           deadline_ms=0.5)
    _time.sleep(0.005)                       # let the tight deadline die
    out = engine.flush()
    engine.faults = None
    assert all(not isinstance(out[r], ServeError) for r in good)
    assert isinstance(out[doomed], ServeError)
    print(f"\nchaotic tenant: {len(good)} requests survived an injected "
          f"fast-path crash (served degraded), 1 dropped: "
          f"{out[doomed].reason}")
    h = engine.health()
    print("--- engine health ---")
    print(f"{'breakers':>20}: "
          f"{ {k: v['state'] for k, v in h['breakers'].items()} }")
    print(f"{'degraded_served':>20}: {h['degraded_served']}")
    print(f"{'failures':>20}: {h['failures']}")
    print(f"{'deadline':>20}: {h['deadline']}")

    st = engine.stats()
    print("\n--- engine stats ---")
    for key in ("submitted", "served", "flushes", "panels_executed",
                "bucket_occupancy", "padding_waste", "exec_cache_hits",
                "exec_cache_misses", "requests_per_s"):
        val = st[key]
        print(f"{key:>20}: {val:.3f}" if isinstance(val, float)
              else f"{key:>20}: {val}")
    print("--- registry ---")
    for key, val in st["registry"].items():
        if key != "names":
            print(f"{key:>20}: {val}")

    # --- observability: Prometheus exposition + request-lifecycle trace
    expo = engine.metrics.exposition()
    print("\n--- metrics exposition (serve_* series) ---")
    for line in expo.splitlines():
        if line.startswith("serve_") and not line.endswith(" 0"):
            print(line)
    trace = tracer.to_chrome_trace()
    path = os.path.join(tempfile.gettempdir(), "serve_sparse_trace.json")
    with open(path, "w") as f:
        json.dump(trace, f)
        f.write("\n")
    admits = sum(e["name"] == "serve.admit" for e in trace["traceEvents"])
    completes = sum(
        e["name"] == "serve.complete" for e in trace["traceEvents"])
    flows = sum(e.get("cat") == "repro.flow"
                for e in trace["traceEvents"])
    print(f"\nwrote {len(trace['traceEvents'])}-event Perfetto trace "
          f"({admits} admits, {completes} completes, {flows} flow "
          f"events) to {path}")

    # --- the same metrics, scraped over HTTP: what a Prometheus
    #     scraper (or an on-call engineer with curl) sees
    with engine.serve_http() as srv:
        scraped = urllib.request.urlopen(
            f"{srv.url}/metrics", timeout=10).read().decode()
        health = json.loads(urllib.request.urlopen(
            f"{srv.url}/health", timeout=10).read().decode())
        explain = json.loads(urllib.request.urlopen(
            f"{srv.url}/explain/tenantB/fem", timeout=10).read().decode())
    served_line = next(line for line in scraped.splitlines()
                       if line.startswith("serve_served_total"))
    print(f"\nscraped {srv.url}/metrics: "
          f"{len(scraped.splitlines())} exposition lines "
          f"({served_line})")
    print(f"/health: deadline miss rate "
          f"{health['deadline']['miss_rate']:.2f}, "
          f"breakers {sorted(health['breakers'])}")
    print(f"/explain/tenantB/fem: tc_fraction "
          f"{explain['tc_fraction']:.2f}, "
          f"pipeline depth {explain['occupancy']['pipeline_depth']}")
    print("serve_sparse OK")


if __name__ == "__main__":
    main()
