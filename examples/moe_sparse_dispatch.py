"""MoE dispatch through the Libra lens (DESIGN.md §4.3): the token→expert
assignment matrix is an extreme-sparse matrix (every 8×1 column vector is
NNZ-1 — the paper's Fig.-1 left regime), so the 2D-aware distributor
routes 100% of it to the flexible (VPU) path. This example builds that
dispatch matrix explicitly, runs it through LibraSpMM, and checks it
against the production sort-based dispatch in models/moe.py.

    PYTHONPATH=src python examples/moe_sparse_dispatch.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nnz1_fraction
from repro.core.spmm import LibraSpMM
from repro.models import moe
from repro.models.config import ArchConfig
from repro.sparse.matrix import coo_to_csr


def main() -> None:
    rng = np.random.default_rng(0)
    tokens, d, e, k = 64, 32, 8, 2
    x = rng.standard_normal((tokens, d)).astype(np.float32)
    logits = rng.standard_normal((tokens, e)).astype(np.float32)
    topi = np.argsort(-logits, axis=1)[:, :k]
    w = np.ones((tokens, k), np.float32) / k

    # Dispatch matrix D: (e·cap, tokens) — one-hot rows selecting tokens.
    cap = tokens * k // e * 2
    rows_l, cols_l, vals_l = [], [], []
    fill = np.zeros(e, np.int64)
    for t in range(tokens):
        for j in range(k):
            ex = int(topi[t, j])
            if fill[ex] < cap:
                rows_l.append(ex * cap + fill[ex])
                cols_l.append(t)
                vals_l.append(1.0)
                fill[ex] += 1
    dmat = coo_to_csr(e * cap, tokens, np.asarray(rows_l, np.int32),
                      np.asarray(cols_l, np.int32),
                      np.asarray(vals_l, np.float32))

    frac = nnz1_fraction(dmat)
    op = LibraSpMM(dmat)  # 2D-aware distribution decides the path
    print(f"dispatch matrix: {dmat.shape}, nnz={dmat.nnz}, "
          f"NNZ-1 fraction={frac:.2f} → tc_ratio={op.tc_ratio:.2f} "
          f"(Libra sends it to the flexible path, as the paper's Fig. 1 "
          f"extreme-sparse regime predicts)")

    buf = np.asarray(op(jnp.asarray(x))).reshape(e, cap, d)

    # Cross-check vs the production sort-based dispatch.
    cfg = ArchConfig(name="demo", family="moe", n_layers=1, d_model=d,
                     n_heads=2, n_kv=2, d_ff=16, moe_d_ff=16, vocab=128,
                     n_experts=e, top_k=k, capacity_factor=2.0)
    params = moe.init_moe(jax.random.PRNGKey(0), cfg)
    out, aux = moe.moe_block(params, jnp.asarray(x)[None], cfg)
    assert out.shape == (1, tokens, d)
    # Same per-expert token sets (order may differ): compare sums.
    per_expert_sum = buf.sum(axis=1)
    print(f"per-expert dispatched token counts: {fill.tolist()}")
    print(f"moe_block output OK, aux={float(aux):.3f}")
    print("moe_sparse_dispatch OK")


if __name__ == "__main__":
    main()
