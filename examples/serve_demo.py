"""Serving example: batched autoregressive generation with a sharded KV
cache, for a dense arch and an SSM arch (O(1) state decode).

    PYTHONPATH=src python examples/serve_demo.py
"""
from repro.configs import get_smoke_config
from repro.launch.serve import generate


def main() -> None:
    for arch in ("glm4_9b", "mamba2_130m"):
        cfg = get_smoke_config(arch)
        toks, dt = generate(cfg, batch=4, prompt_len=12, gen=12)
        n = toks.shape[0] * toks.shape[1]
        print(f"[{arch}] generated {toks.shape} tokens in {dt:.2f}s "
              f"({n / dt:.1f} tok/s) sample={toks[0][:6].tolist()}")
    print("serve_demo OK")


if __name__ == "__main__":
    main()
